"""Continuum's TTL utility model (paper §4.1–4.2).

For a finished request r that will call tool f:

    Cost(τ, r)    = MemUsage(r)/M̄ · τ
    Benefit(r)    = CacheMissCost(r) + OutOfOrderCost(r)
    CacheMissCost = MemUsage(r)/M̄ · PrefillReload(r)
    OutOfOrderCost= T̄/M̄ · MemUsage(r) · η

After cancelling MemUsage(r)/M̄ (Eq. 2):

    τ* = argmax_τ  P(τ, f) · (T̄·η + PrefillReload(r)) − τ

solved by enumerating the empirical tool-duration records S[f] (plus τ=0).

Cold start (paper §4.2): with |S| ≤ K use a fixed TTL derived from the same
model under ToolDuration ~ Exp(mean u), η = 1:
    maximize (1 − e^{−τ/u})·G − τ  ⇒  τ* = u · ln(G/u)  (if G > u, else 0).
With K < |S| and |S[f]| ≤ K, fall back to the global duration records.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict, deque
from typing import Optional

import numpy as np


@dataclasses.dataclass
class TTLConfig:
    cold_start_k: int = 100         # K in the paper
    max_ttl: float = 600.0          # hard bound (robustness backstop)
    exp_unit_mean: float = 1.0      # u for the cold-start Exp model (seconds)
    window: int = 512               # sliding windows for T̄ and M̄
    eta_default: float = 1.0        # memoryfulness before enough samples
    eta_min_programs: int = 8
    per_tool_cap: int = 2048        # bound S[f] memory


class ToolDurationRecords:
    """S in Algorithm 1: per-tool and global empirical duration records."""

    def __init__(self, cap: int = 2048):
        self.cap = cap
        self.per_tool: dict[str, deque] = defaultdict(lambda: deque(maxlen=cap))
        self.global_: deque = deque(maxlen=cap * 4)

    def record(self, tool: str, duration: float) -> None:
        d = max(0.0, float(duration))
        self.per_tool[tool].append(d)
        self.global_.append(d)

    def count(self, tool: Optional[str] = None) -> int:
        if tool is None:
            return len(self.global_)
        return len(self.per_tool.get(tool, ()))

    def durations(self, tool: Optional[str] = None) -> np.ndarray:
        src = self.global_ if tool is None else self.per_tool.get(tool, ())
        return np.asarray(src, dtype=np.float64)

    def cdf(self, tool: Optional[str], tau: float) -> float:
        """P(τ, f): empirical P[duration <= tau]."""
        d = self.durations(tool)
        if d.size == 0:
            return 0.0
        return float(np.mean(d <= tau))


class MemoryfulnessEstimator:
    """η = −Corr(k, N−k) over (served, remaining) samples of finished
    programs (paper §4.1). Streaming Pearson correlation."""

    def __init__(self, default: float = 1.0, min_programs: int = 8):
        self.default = default
        self.min_programs = min_programs
        self.n_programs = 0
        self._sx = self._sy = self._sxx = self._syy = self._sxy = 0.0
        self._n = 0

    def observe_program(self, num_turns: int) -> None:
        """Add samples (k, N−k) for k = 0..N−1 from a finished program."""
        N = int(num_turns)
        if N <= 0:
            return
        self.n_programs += 1
        for k in range(N):
            x, y = float(k), float(N - k)
            self._n += 1
            self._sx += x
            self._sy += y
            self._sxx += x * x
            self._syy += y * y
            self._sxy += x * y

    @property
    def eta(self) -> float:
        if self.n_programs < self.min_programs or self._n < 4:
            return self.default
        n = self._n
        cov = self._sxy / n - (self._sx / n) * (self._sy / n)
        vx = self._sxx / n - (self._sx / n) ** 2
        vy = self._syy / n - (self._sy / n) ** 2
        if vx <= 1e-12 or vy <= 1e-12:
            # all programs identical length -> fully memoryful
            return 1.0
        corr = cov / math.sqrt(vx * vy)
        return float(np.clip(-corr, -1.0, 1.0))


class SlidingMean:
    def __init__(self, window: int, init: float = 0.0):
        self.buf: deque = deque(maxlen=window)
        self.init = init

    def add(self, x: float) -> None:
        self.buf.append(float(x))

    @property
    def mean(self) -> float:
        if not self.buf:
            return self.init
        return float(np.mean(self.buf))


@dataclasses.dataclass
class TTLDecision:
    ttl: float
    gain: float                    # expected net benefit at τ*
    source: str                    # "per_tool" | "global" | "cold_start"
    prefill_reload: float
    eta: float
    t_bar: float


class TTLModel:
    """Computes τ* (Eq. 2) from live statistics.

    The engine feeds it: tool durations (via records), queueing delays of
    evicted-then-returning requests (T̄), request memory usage (M̄), and
    finished program turn counts (η).
    """

    def __init__(self, cfg: TTLConfig = TTLConfig()):
        self.cfg = cfg
        self.records = ToolDurationRecords(cfg.per_tool_cap)
        self.eta_est = MemoryfulnessEstimator(cfg.eta_default, cfg.eta_min_programs)
        self.t_bar = SlidingMean(cfg.window, init=0.0)    # avg queueing delay
        self.m_bar = SlidingMean(cfg.window, init=1.0)    # avg mem per request
        # telemetry: a repro.obs.audit.TTLAudit records every solve's
        # inputs and output; None (the default) costs one attribute test
        self.audit = None

    # ---- feeds ----------------------------------------------------------
    def observe_tool(self, tool: str, duration: float) -> None:
        self.records.record(tool, duration)

    def observe_queueing_delay(self, delay: float) -> None:
        self.t_bar.add(max(0.0, delay))

    def observe_mem_usage(self, mem: float) -> None:
        if mem > 0:
            self.m_bar.add(mem)

    def observe_program_finish(self, num_turns: int) -> None:
        self.eta_est.observe_program(num_turns)

    def predict_tool_duration(self, tool: Optional[str]) -> float:
        """Point prediction of the coming tool call's duration — the
        expectation of the same empirical records the solver's CDF draws
        from (per-tool mean when the tool has records, else the global
        mean, else the cold-start Exp mean). The drift watchdog pairs
        this with the realized gap to audit the tool-CDF estimator."""
        d = self.records.durations(tool) if tool else \
            self.records.durations(None)
        if d.size == 0:
            d = self.records.durations(None)
        if d.size == 0:
            return self.cfg.exp_unit_mean
        return float(d.mean())

    # ---- the solver ------------------------------------------------------
    def _gain_term(self, prefill_reload: float,
                   queue_eta: Optional[float] = None) -> float:
        """G = T̄·η + PrefillReload(r) (seconds).

        ``queue_eta`` — a live per-replica queueing-delay estimate (the
        engine's outstanding-work ETA) — replaces the fleet-average T̄ when
        provided: in a multi-replica cluster the out-of-order cost a TTL
        miss pays is the *local* queue the returning program would rejoin,
        not the historical average across the fleet.

        The estimate prices each queued request's residual prefill
        separately (lumping them into one quadratic-attention call
        overestimates replicas holding many small residuals, biasing this
        solver toward over-pinning) and includes the waiting queue's
        decode backlog. The same signal drives the cluster's
        ``ScalingPolicy``, so TTL solving and fleet sizing read one
        consistent notion of queueing pressure."""
        delay = self.t_bar.mean if queue_eta is None else max(0.0, queue_eta)
        return delay * self.eta_est.eta + max(0.0, prefill_reload)

    def solve(self, tool: Optional[str], prefill_reload: float,
              queue_eta: Optional[float] = None) -> TTLDecision:
        dec = self._solve(tool, prefill_reload, queue_eta)
        if self.audit is not None:
            self.audit.record_solve(
                tool, prefill_reload, queue_eta, dec,
                n_tool=self.records.count(tool) if tool else 0,
                n_global=self.records.count(None))
        return dec

    def _solve(self, tool: Optional[str], prefill_reload: float,
               queue_eta: Optional[float] = None) -> TTLDecision:
        cfg = self.cfg
        G = self._gain_term(prefill_reload, queue_eta)
        eta = self.eta_est.eta
        tb = self.t_bar.mean if queue_eta is None else max(0.0, queue_eta)

        n_global = self.records.count(None)
        n_tool = self.records.count(tool) if tool else 0

        if n_global <= cfg.cold_start_k:
            ttl = self._cold_start_ttl(G)
            return TTLDecision(min(ttl, cfg.max_ttl), 0.0, "cold_start",
                               prefill_reload, eta, tb)

        source = "per_tool" if (tool and n_tool > cfg.cold_start_k) else "global"
        d = self.records.durations(tool if source == "per_tool" else None)
        tau, gain = self._argmax_over_durations(d, G)
        if gain <= 0.0:
            return TTLDecision(0.0, gain, source, prefill_reload, eta, tb)
        return TTLDecision(min(tau, cfg.max_ttl), gain, source,
                           prefill_reload, eta, tb)

    @staticmethod
    def _argmax_over_durations(d: np.ndarray, G: float) -> tuple[float, float]:
        """Enumerate candidate τ ∈ sorted unique durations ∪ {0} (Eq. 2)."""
        if d.size == 0:
            return 0.0, 0.0
        taus = np.unique(d)                      # sorted unique
        n = d.size
        # P(τ_i) = rank of τ_i / n  (counts duplicates correctly)
        cdf = np.searchsorted(np.sort(d), taus, side="right") / n
        gains = cdf * G - taus
        i = int(np.argmax(gains))
        best_gain = float(gains[i])
        zero_gain = 0.0                          # τ=0 ⇒ gain 0
        if best_gain <= zero_gain:
            return 0.0, best_gain
        return float(taus[i]), best_gain

    def _cold_start_ttl(self, G: float) -> float:
        """T_default: Exp(u) durations, η=1 ⇒ τ* = u·ln(G/u) if G > u."""
        u = self.cfg.exp_unit_mean
        if G <= u:
            return 0.0
        return u * math.log(G / u)

    # ---- parallel tool calls (paper Appendix C.1) -------------------------
    def solve_parallel(self, tools: list[str], prefill_reload: float,
                       queue_eta: Optional[float] = None) -> TTLDecision:
        """TTL for a turn that fans out several tools and resumes when ALL
        return: the finish-within-τ probability is the product of the
        per-tool empirical CDFs (independent tools; the gap is the max of
        the durations). Candidates: union of all tools' recorded durations.
        """
        if len(tools) <= 1:
            return self.solve(tools[0] if tools else None, prefill_reload,
                              queue_eta)
        dec = self._solve_parallel(tools, prefill_reload, queue_eta)
        if self.audit is not None:
            self.audit.record_solve(
                "par:" + "+".join(sorted(tools)), prefill_reload, queue_eta,
                dec, n_tool=min(self.records.count(f) for f in tools),
                n_global=self.records.count(None))
        return dec

    def _solve_parallel(self, tools: list[str], prefill_reload: float,
                        queue_eta: Optional[float] = None) -> TTLDecision:
        cfg = self.cfg
        G = self._gain_term(prefill_reload, queue_eta)
        if self.records.count(None) <= cfg.cold_start_k:
            ttl = self._cold_start_ttl(G)
            return TTLDecision(min(ttl, cfg.max_ttl), 0.0, "cold_start",
                               prefill_reload, self.eta_est.eta, self.t_bar.mean)
        cands = [0.0]
        per_tool = []
        for f in tools:
            src = f if self.records.count(f) > cfg.cold_start_k else None
            d = self.records.durations(src)
            per_tool.append(np.sort(d))
            cands.extend(np.unique(d).tolist())
        taus = np.unique(np.asarray(cands))
        joint = np.ones_like(taus)
        for d in per_tool:
            if d.size == 0:
                joint *= 0.0
            else:
                joint *= np.searchsorted(d, taus, side="right") / d.size
        gains = joint * G - taus
        i = int(np.argmax(gains))
        if gains[i] <= 0:
            return TTLDecision(0.0, float(gains[i]), "parallel",
                               prefill_reload, self.eta_est.eta, self.t_bar.mean)
        return TTLDecision(min(float(taus[i]), cfg.max_ttl), float(gains[i]),
                           "parallel", prefill_reload, self.eta_est.eta,
                           self.t_bar.mean)
