"""Beyond-paper experiment: TTL benefit vs workload memoryfulness η.

The paper's §4.1 theory predicts the OutOfOrderCost term (and hence the
queueing-delay part of the TTL benefit) scales with η = −Corr(k, N−k):
fixed-turn-count programs (η≈1) benefit most; geometric/memoryless turn
counts (η≈0) should gain only the prefill-reuse part. This bench
constructs workloads at both extremes (same mean turns, tokens, tools) and
measures the Continuum-vs-vLLM gain + the η the estimator actually learns.
"""
import dataclasses

import numpy as np

from benchmarks.common import emit, run_one, save_rows

import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.sim.workload import SWE_BENCH, WORKLOADS, generate_programs


def make_geometric_variant(seed: int, n: int, rate: float):
    """Same marginal stats as SWE-Bench but geometric turn counts."""
    rng = np.random.default_rng(seed)
    programs = generate_programs(SWE_BENCH, n=n, rate_jps=rate, seed=seed)
    # resample turn counts geometrically with the same mean (10.9)
    out = []
    for p in programs:
        n_turns = max(2, int(rng.geometric(1.0 / 10.9)))
        turns = (p.turns * ((n_turns // len(p.turns)) + 1))[:n_turns]
        turns = [dataclasses.replace(t) for t in turns]
        for t in turns[:-1]:
            if t.tool is None:
                t.tool, t.tool_duration = "ls", 0.2
        turns[-1] = dataclasses.replace(turns[-1], tool=None, tool_duration=0.0)
        p2 = dataclasses.replace(p, turns=turns)
        out.append(p2)
    return out


def run(quick: bool = True) -> list[dict]:
    n = 50 if quick else 120
    rate = 0.055
    rows = []
    # memoryful extreme: fixed turn counts (std ~ 0)
    fixed = dataclasses.replace(SWE_BENCH, std_turns=0.01)
    WORKLOADS["swe-fixed"] = fixed
    for policy in ("vllm", "continuum"):
        r = run_one(policy, workload="swe-fixed", n=n, rate=rate)
        rows.append({**r, "regime": "memoryful(fixed N)"})
    # memoryless extreme handled via the geometric resampler + direct run
    from repro.configs import get_config
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.profiler import HardwareProfile
    from repro.sim.runner import run_workload
    for policy in ("vllm", "continuum"):
        eng = Engine(get_config("glm4-9b"),
                     EngineConfig(policy=policy, chips=8, max_batch=48,
                                  chunk_size=2048, kv_budget_bytes=40e9),
                     HardwareProfile())
        programs = make_geometric_variant(0, n, rate)
        s = run_workload(programs, [eng], max_seconds=1e7)
        eta = eng.scheduler.handler.ttl_model.eta_est.eta
        rows.append({"policy": policy, "workload": "swe-geometric",
                     "rate": rate, "avg_jct": s.avg_jct, "p95": s.p95_jct,
                     "throughput_jpm": s.throughput_jobs_per_s * 60,
                     "queueing": s.avg_queueing,
                     "ttl_hit_rate": s.avg_ttl_hit_rate,
                     "eta_learned": eta, "regime": "memoryless(geom N)"})
    save_rows("beyond_memoryfulness", rows)
    vf = next(r for r in rows if r["regime"].startswith("memoryful")
              and r["policy"] == "vllm")
    cf = next(r for r in rows if r["regime"].startswith("memoryful")
              and r["policy"] == "continuum")
    vg = next(r for r in rows if r["regime"].startswith("memoryless")
              and r["policy"] == "vllm")
    cg = next(r for r in rows if r["regime"].startswith("memoryless")
              and r["policy"] == "continuum")
    emit("beyond.eta.memoryful_gain", vf["avg_jct"] / max(cf["avg_jct"], 1e-9),
         "fixed turn counts (eta~1)")
    emit("beyond.eta.memoryless_gain", vg["avg_jct"] / max(cg["avg_jct"], 1e-9),
         f"geometric turn counts; eta learned={cg.get('eta_learned', 0):.2f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
