"""Paper Table 4: scheduler overhead (wall-clock per Schedule() call) per
policy, with and without offloading enabled."""
import time

from benchmarks.common import emit, save_rows

import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.core.policies import make_policy
from repro.core.scheduler import Scheduler
from repro.core.tool_handler import ToolCallHandler
from repro.core.ttl import TTLModel
from repro.core.types import Request
from repro.serving.blocks import BlockConfig, BlockManager
from repro.serving.offload import OffloadConfig, OffloadManager


def measure(policy: str, offload: bool, n_wait: int = 256,
            iters: int = 200, telemetry: bool = False,
            raw: bool = False, tel=None):
    handler = ToolCallHandler(TTLModel(), prefill_reload_fn=lambda r: 1.0)
    for i in range(200):
        handler.ttl_model.observe_tool(f"t{i % 8}", 0.5 + i % 5)
    off = OffloadManager(OffloadConfig()) if offload else None
    if telemetry and tel is None:
        from repro.obs import Telemetry
        tel = Telemetry()
        # the gate prices the *full* plane: drift predict/realize pairs
        # ride the same scheduler hot path as trace + audit
        tel.enable_drift()
    times = []
    for it in range(iters):
        blocks = BlockManager(BlockConfig(100000, 16))
        sched = Scheduler(make_policy(policy), handler, blocks, off)
        sched._kv_bytes_per_token = 4e4
        if tel is not None:
            sched.obs = tel
            sched.obs_replica = "bench"
            handler.obs = tel
            handler.obs_replica = "bench"
            handler.ttl_model.audit = tel.audit
        for i in range(n_wait):
            sched.on_request_arrive(
                Request(program_id=f"p{i}", turn_idx=i % 5, prompt_len=4096,
                        output_len=256, arrival_time=float(i),
                        program_arrival_time=float(i), tool="ls"), float(i))
        # timeit-style: GC pauses land on whichever variant crosses an
        # allocation threshold mid-call — amortized noise, not scheduler
        # cost, so keep it out of the timed region
        import gc
        was_enabled = gc.isenabled()
        gc.disable()
        t0 = time.perf_counter()
        sched.schedule(float(n_wait), max_admits=64)
        times.append(time.perf_counter() - t0)
        if was_enabled:
            gc.enable()
        if tel is not None:
            handler.obs = None
            handler.ttl_model.audit = None
    if raw:
        return [t * 1000.0 for t in times]
    # mean ms per Schedule() over a 256-deep queue
    return sum(times) / iters * 1000.0


def run(quick: bool = True) -> list[dict]:
    iters = 30 if quick else 200
    rows = []
    for policy in ("vllm", "autellix", "infercept", "continuum"):
        for off in (False, True):
            ms = measure(policy, off, iters=iters)
            rows.append({"policy": policy, "offload": off, "ms_per_step": ms})
    save_rows("table4_overhead", rows)
    ours = next(r for r in rows if r["policy"] == "continuum" and not r["offload"])
    base = next(r for r in rows if r["policy"] == "vllm" and not r["offload"])
    emit("table4.continuum_sched_ms", ours["ms_per_step"],
         f"vllm={base['ms_per_step']:.3f}ms (single-digit-ms class)")
    return rows


def run_telemetry_gate(max_overhead: float = 0.03,
                       pairs: int = 80, http: bool = False) -> bool:
    """CI gate for the telemetry plane: the *enabled* Schedule() overhead
    (trace instants + audit links + counters on every decision, plus the
    drift watchdog's predict/realize pairs on every solve and admission)
    must stay under ``max_overhead`` of the uninstrumented call.

    Estimator: ``pairs`` back-to-back off/on single-call timings; the
    statistic is the **median of per-pair on/off ratios**. Shared-host
    noise drifts on a timescale much longer than one pair, so each
    ratio sees the same floor and the drift cancels; a global best-of
    or mean estimator compares samples from *different* noise regimes
    and swings wildly (observed ±25% run to run, vs ~±0.5% for the
    paired median).

    With ``http``, every "on" run shares one Telemetry plane served by a
    live :class:`~repro.obs.server.ObsServer` while a background thread
    scrapes ``/metrics`` in a loop — the gate then also bounds the cost
    of concurrent scrapes racing the hot path (readers retry on dict
    mutation; the scheduler never waits on them). The verdict lands in
    ``experiments/bench/BENCH_obs.json``."""
    tel = server = scraper = None
    scrapes = {"n": 0, "errors": 0}
    stop = False
    if http:
        import threading
        import urllib.request

        from repro.obs import Telemetry
        from repro.obs.server import ObsServer
        tel = Telemetry()
        tel.enable_drift()
        server = ObsServer(tel, clock=lambda: 0.0).start()
        url = server.url("/metrics")

        def _scrape_loop():
            # 20 Hz is already ~300x Prometheus's default 15 s interval;
            # a zero-sleep loop would measure pure GIL contention, not
            # the cost a real scraper imposes
            while not stop:
                try:
                    with urllib.request.urlopen(url, timeout=2) as r:
                        r.read()
                    scrapes["n"] += 1
                except Exception:
                    scrapes["errors"] += 1
                time.sleep(0.05)

        scraper = threading.Thread(target=_scrape_loop, daemon=True)
        scraper.start()
    try:
        ratios = []
        for _ in range(pairs):
            off = measure("continuum", True, iters=1, raw=True)[0]
            on = measure("continuum", True, iters=1, telemetry=True,
                         raw=True, tel=tel)[0]
            ratios.append(on / off)
    finally:
        stop = True
        if scraper is not None:
            scraper.join(timeout=5)
        if server is not None:
            server.stop()
    ratios.sort()
    overhead = ratios[len(ratios) // 2] - 1.0
    ok = overhead <= max_overhead
    tag = " under live /metrics scrapes" if http else ""
    emit("table4.telemetry_overhead_frac", max(overhead, 0.0),
         f"median paired ratio over {pairs} pairs{tag}, "
         f"limit={max_overhead:.0%} {'ok' if ok else 'FAIL'}")
    row = {"pairs": pairs, "overhead": overhead,
           "p25": ratios[len(ratios) // 4] - 1.0,
           "p75": ratios[3 * len(ratios) // 4] - 1.0,
           "limit": max_overhead, "http": http,
           "scrapes": scrapes["n"], "scrape_errors": scrapes["errors"],
           "ok": ok}
    save_rows("table4_telemetry_overhead", [row])
    if http:
        import json
        from benchmarks.common import RESULTS_DIR
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        (RESULTS_DIR / "BENCH_obs.json").write_text(
            json.dumps(row, indent=2, sort_keys=True) + "\n")
    return ok


if __name__ == "__main__":
    import sys as _sys
    if "--telemetry" in _sys.argv:
        _sys.exit(0 if run_telemetry_gate(
            http="--http" in _sys.argv) else 1)
    run(quick=False)
