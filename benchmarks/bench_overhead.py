"""Paper Table 4: scheduler overhead (wall-clock per Schedule() call) per
policy, with and without offloading enabled."""
import time

from benchmarks.common import emit, save_rows

import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.core.policies import make_policy
from repro.core.scheduler import Scheduler
from repro.core.tool_handler import ToolCallHandler
from repro.core.ttl import TTLModel
from repro.core.types import Request
from repro.serving.blocks import BlockConfig, BlockManager
from repro.serving.offload import OffloadConfig, OffloadManager


def measure(policy: str, offload: bool, n_wait: int = 256,
            iters: int = 200) -> float:
    handler = ToolCallHandler(TTLModel(), prefill_reload_fn=lambda r: 1.0)
    for i in range(200):
        handler.ttl_model.observe_tool(f"t{i % 8}", 0.5 + i % 5)
    off = OffloadManager(OffloadConfig()) if offload else None
    total = 0.0
    for it in range(iters):
        blocks = BlockManager(BlockConfig(100000, 16))
        sched = Scheduler(make_policy(policy), handler, blocks, off)
        sched._kv_bytes_per_token = 4e4
        for i in range(n_wait):
            sched.on_request_arrive(
                Request(program_id=f"p{i}", turn_idx=i % 5, prompt_len=4096,
                        output_len=256, arrival_time=float(i),
                        program_arrival_time=float(i), tool="ls"), float(i))
        t0 = time.perf_counter()
        sched.schedule(float(n_wait), max_admits=64)
        total += time.perf_counter() - t0
    return total / iters * 1000.0  # ms per Schedule() over a 256-deep queue


def run(quick: bool = True) -> list[dict]:
    iters = 30 if quick else 200
    rows = []
    for policy in ("vllm", "autellix", "infercept", "continuum"):
        for off in (False, True):
            ms = measure(policy, off, iters=iters)
            rows.append({"policy": policy, "offload": off, "ms_per_step": ms})
    save_rows("table4_overhead", rows)
    ours = next(r for r in rows if r["policy"] == "continuum" and not r["offload"])
    base = next(r for r in rows if r["policy"] == "vllm" and not r["offload"])
    emit("table4.continuum_sched_ms", ours["ms_per_step"],
         f"vllm={base['ms_per_step']:.3f}ms (single-digit-ms class)")
    return rows


if __name__ == "__main__":
    run(quick=False)
