"""§Roofline: per (arch x shape x mesh) three-term roofline from the
dry-run's compiled HLO (see repro/dist/roofline.py for methodology).

MODEL_FLOPS per cell:
  train:   3 * 6 * N_active * tokens   (fwd+bwd = 3x fwd, 2*N per token fwd)
           -- reported as 6*N*D per the assignment; the 3x is folded into
              the useful-ratio denominator notes
  prefill: 2 * N_active * tokens (+ attention quadratic term)
  decode:  2 * N_active * batch (+ KV-cache read is memory, not flops)
"""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import SHAPES, get_config                 # noqa: E402
from repro.configs.base import arch_shape_cells              # noqa: E402
from repro.dist.roofline import roofline                      # noqa: E402

ART = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
OUT = Path(__file__).resolve().parents[1] / "experiments" / "roofline"


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n_act * tokens
        # causal attention term: 2*2*kv_elems_per_token * S/2 per token
        kv_elems = cfg.kv_bytes_per_token(2) / 2
        flops += 2.0 * tokens * (shape.seq_len / 2) * kv_elems
        return flops
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch


def analyze_cell(arch: str, shape_name: str, mesh_tag: str) -> dict | None:
    stem = f"{arch}_{shape_name}_{mesh_tag}"
    hlo = ART / f"{stem}.hlo.txt"
    meta = ART / f"{stem}.json"
    if not hlo.exists() or not meta.exists():
        return None
    rec = json.loads(meta.read_text())
    chips = rec["chips"]
    t = roofline(hlo.read_text(), chips=chips,
                 model_flops=model_flops(arch, shape_name))
    terms = {"compute": t.compute_s, "memory": t.memory_s,
             "collective": t.collective_s}
    dom = max(terms.values())
    total = t.compute_s + t.memory_s + t.collective_s
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag, "chips": chips,
        "compute_s": t.compute_s, "memory_s": t.memory_s,
        "collective_s": t.collective_s, "bottleneck": t.bottleneck,
        "hlo_flops_per_dev": t.flops, "hbm_bytes_per_dev": t.bytes,
        "coll_bytes_per_dev": t.coll_bytes,
        "model_flops": t.model_flops,
        "useful_ratio": t.useful_ratio,
        # roofline fraction: the ideal-compute time over the bound implied
        # by the dominant term (how close this cell is to its roofline)
        "roofline_fraction": (t.model_flops / (chips * 197e12)) / max(dom, 1e-12),
        "peak_gib": rec.get("peak_bytes_estimate", 0) / 2**30,
        "top_dots": t.top_dots[:3],
        "top_colls": t.top_colls[:3],
    }


def run(quick: bool = True, mesh_tags=("16x16",)) -> list[dict]:
    rows = []
    for arch, shape in arch_shape_cells():
        for tag in mesh_tags:
            r = analyze_cell(arch, shape, tag)
            if r:
                rows.append(r)
    OUT.mkdir(parents=True, exist_ok=True)
    ser = [{k: (v if not isinstance(v, list) else str(v)) for k, v in r.items()}
           for r in rows]
    (OUT / "baseline.json").write_text(json.dumps(ser, indent=1))
    # markdown table for EXPERIMENTS.md
    lines = ["| arch | shape | mesh | compute_s | memory_s | collective_s | "
             "bottleneck | useful | roofline_frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | {r['bottleneck']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    (OUT / "baseline.md").write_text("\n".join(lines))
    from benchmarks.common import emit
    if rows:
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        best = max(rows, key=lambda r: r["roofline_fraction"])
        emit("roofline.cells_analyzed", len(rows), "")
        emit("roofline.worst_fraction", worst["roofline_fraction"],
             f"{worst['arch']}/{worst['shape']} ({worst['bottleneck']}-bound)")
        emit("roofline.best_fraction", best["roofline_fraction"],
             f"{best['arch']}/{best['shape']}")
    return rows


if __name__ == "__main__":
    tags = ("16x16", "2x16x16") if "--all-meshes" in sys.argv else ("16x16",)
    rows = run(quick=False, mesh_tags=tags)
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
              f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
              f"x={r['collective_s']:.2e} dom={r['bottleneck']:10s} "
              f"useful={r['useful_ratio']:5.2f} frac={r['roofline_fraction']:.3f}")
