"""§Roofline: per (arch x shape x mesh) three-term roofline from the
dry-run's compiled HLO (see repro/dist/roofline.py for methodology).

Analyzes every saved dry-run artifact in experiments/dryrun/ (full
pod-scale cells and --smoke cells alike — the .json sidecar carries the
config flavor and the actual seq/batch the cell was lowered with).

MODEL_FLOPS per cell:
  train:   3 * 2 * N_active * tokens   (fwd+bwd = 3x fwd, 2*N per token fwd)
  prefill: 2 * N_active * tokens (+ attention quadratic term)
  decode:  2 * N_active * batch (+ KV-cache read is memory, not flops)

Outputs:
  experiments/roofline/baseline.json / baseline.md   (full rows + table)
  experiments/bench/roofline.csv                     (flat CSV, one row/cell)
"""
import csv
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from repro.configs import get_config                          # noqa: E402
from repro.dist.roofline import roofline                      # noqa: E402

ART = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
OUT = Path(__file__).resolve().parents[1] / "experiments" / "roofline"
BENCH_OUT = Path(__file__).resolve().parents[1] / "experiments" / "bench"

CSV_FIELDS = ["arch", "shape", "mesh", "chips", "smoke", "kind",
              "seq_len", "global_batch", "compute_s", "memory_s",
              "collective_s", "bottleneck", "hlo_flops_per_dev",
              "hbm_bytes_per_dev", "coll_bytes_per_dev", "model_flops",
              "useful_ratio", "roofline_fraction", "peak_gib"]


def model_flops(rec: dict) -> float:
    cfg = get_config(rec["arch"], smoke=rec.get("smoke", False))
    kind = rec.get("kind", "train")
    seq = rec.get("seq_len", 0)
    batch = rec.get("global_batch", 0)
    n_act = cfg.active_param_count()
    if kind == "train":
        return 3.0 * 2.0 * n_act * batch * seq
    if kind == "prefill":
        tokens = batch * seq
        flops = 2.0 * n_act * tokens
        # causal attention term: 2*2*kv_elems_per_token * S/2 per token
        kv_elems = cfg.kv_bytes_per_token(2) / 2
        flops += 2.0 * tokens * (seq / 2) * kv_elems
        return flops
    # decode: one token per sequence
    return 2.0 * n_act * batch


def _legacy_fill(rec: dict) -> dict:
    """Artifacts from before the smoke-cell metadata: derive kind/seq/batch
    from the canonical SHAPES entry."""
    if "kind" not in rec:
        from repro.configs import SHAPES
        shape = SHAPES[rec["shape"]]
        rec = {**rec, "smoke": False, "kind": shape.kind,
               "seq_len": shape.seq_len, "global_batch": shape.global_batch}
    return rec


def analyze_artifact(meta_path: Path, rec: dict) -> dict | None:
    hlo = meta_path.parent / (meta_path.name[:-5] + ".hlo.txt")
    if not hlo.exists():
        return None
    rec = _legacy_fill(rec)
    chips = rec["chips"]
    t = roofline(hlo.read_text(), chips=chips, model_flops=model_flops(rec))
    terms = {"compute": t.compute_s, "memory": t.memory_s,
             "collective": t.collective_s}
    dom = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips, "smoke": rec.get("smoke", False),
        "kind": rec.get("kind", ""), "seq_len": rec.get("seq_len", 0),
        "global_batch": rec.get("global_batch", 0),
        "compute_s": t.compute_s, "memory_s": t.memory_s,
        "collective_s": t.collective_s, "bottleneck": t.bottleneck,
        "hlo_flops_per_dev": t.flops, "hbm_bytes_per_dev": t.bytes,
        "coll_bytes_per_dev": t.coll_bytes,
        "model_flops": t.model_flops,
        "useful_ratio": t.useful_ratio,
        # roofline fraction: the ideal-compute time over the bound implied
        # by the dominant term (how close this cell is to its roofline)
        "roofline_fraction": (t.model_flops / (chips * 197e12)) / max(dom, 1e-12),
        "peak_gib": rec.get("peak_bytes_estimate", 0) / 2**30,
        "top_dots": t.top_dots[:3],
        "top_colls": t.top_colls[:3],
    }


def run(quick: bool = True, mesh_tags=None) -> list[dict]:
    rows = []
    for meta in sorted(ART.glob("*.json")):
        rec = json.loads(meta.read_text())
        if mesh_tags and rec.get("mesh") not in mesh_tags:
            continue
        r = analyze_artifact(meta, rec)
        if r:
            rows.append(r)
    OUT.mkdir(parents=True, exist_ok=True)
    ser = [{k: (v if not isinstance(v, list) else str(v)) for k, v in r.items()}
           for r in rows]
    (OUT / "baseline.json").write_text(json.dumps(ser, indent=1))
    # markdown table for EXPERIMENTS.md
    lines = ["| arch | shape | mesh | compute_s | memory_s | collective_s | "
             "bottleneck | useful | roofline_frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | {r['bottleneck']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    (OUT / "baseline.md").write_text("\n".join(lines))
    # flat CSV for downstream tooling
    BENCH_OUT.mkdir(parents=True, exist_ok=True)
    with (BENCH_OUT / "roofline.csv").open("w", newline="") as fh:
        w = csv.DictWriter(fh, fieldnames=CSV_FIELDS, extrasaction="ignore")
        w.writeheader()
        for r in rows:
            w.writerow(r)
    from benchmarks.common import emit
    if rows:
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        best = max(rows, key=lambda r: r["roofline_fraction"])
        emit("roofline.cells_analyzed", len(rows), "")
        emit("roofline.worst_fraction", worst["roofline_fraction"],
             f"{worst['arch']}/{worst['shape']} ({worst['bottleneck']}-bound)")
        emit("roofline.best_fraction", best["roofline_fraction"],
             f"{best['arch']}/{best['shape']}")
    return rows


if __name__ == "__main__":
    tags = None
    if "--full-only" in sys.argv:
        tags = ("16x16", "2x16x16")
    rows = run(quick=False, mesh_tags=tags)
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
              f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
              f"x={r['collective_s']:.2e} dom={r['bottleneck']:10s} "
              f"useful={r['useful_ratio']:5.2f} frac={r['roofline_fraction']:.3f}")
