"""Paper Fig. 12 + §6.2 distributed setting: multi-engine fleet with
session-aware routing (Continuum) vs round-robin baselines; straggler
mitigation via migration."""
from benchmarks.common import emit, run_one, save_rows


def run(quick: bool = True) -> list[dict]:
    n = 60 if quick else 150
    rate = 0.16                                       # fleet-level load (4x)
    rows = []
    for policy, router in (("vllm", "round_robin"),
                           ("continuum", "round_robin"),
                           ("continuum", "session")):
        r = run_one(policy, n=n, rate=rate, n_engines=4, offload=200e9,
                    router_policy=router)
        rows.append({**r, "router": router})
    save_rows("fig12_distributed", rows)
    rr = next(r for r in rows if r["router"] == "round_robin"
              and r["policy"] == "continuum")
    ses = next(r for r in rows if r["router"] == "session")
    v = next(r for r in rows if r["policy"] == "vllm")
    emit("fig12.session_vs_roundrobin_jct", rr["avg_jct"] / max(ses["avg_jct"], 1e-9),
         "session-aware routing preserves TTL hits")
    emit("fig12.continuum_vs_vllm_fleet", v["avg_jct"] / max(ses["avg_jct"], 1e-9),
         f"fleet of 4 engines @ {rate} jps")
    return rows


if __name__ == "__main__":
    run(quick=False)
