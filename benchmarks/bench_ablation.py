"""Paper Fig. 16: contribution of individual ideas — program-level FCFS,
static TTL (cold-start formula), full Continuum."""
from benchmarks.common import ABLATIONS, emit, run_one, save_rows


def run(quick: bool = True) -> list[dict]:
    n = 40 if quick else 100
    rows = [run_one(p, n=n, rate=0.055) for p in ABLATIONS]
    save_rows("fig16_ablation", rows)
    base = rows[0]["avg_jct"]
    prev = base
    for r in rows[1:]:
        emit(f"fig16.{r['policy']}.cumulative_speedup",
             base / max(r["avg_jct"], 1e-9),
             f"delta vs prev={prev / max(r['avg_jct'], 1e-9):.3f}")
        prev = r["avg_jct"]
    return rows


if __name__ == "__main__":
    run(quick=False)
