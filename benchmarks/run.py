"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per the harness contract) and
writes full CSVs to experiments/bench/. ``--full`` uses paper-scale sizes.
"""
import sys
import time


def main() -> None:
    quick = "--full" not in sys.argv
    from benchmarks import (bench_ablation, bench_cluster, bench_decode,
                            bench_distributed, bench_e2e, bench_elastic,
                            bench_kvstore, bench_memoryfulness,
                            bench_offload, bench_overhead,
                            bench_prefix_sharing, bench_roofline,
                            bench_rollout, bench_sensitivity, bench_tail,
                            bench_turns)
    benches = [
        ("fig8_e2e", bench_e2e.run),
        ("decode", bench_decode.run),
        ("prefix_sharing", bench_prefix_sharing.run),
        ("fig10_offload", bench_offload.run),
        ("kvstore", bench_kvstore.run),
        ("cluster", bench_cluster.run),
        ("elastic", bench_elastic.run),
        ("fig11_tail", bench_tail.run),
        ("fig12_distributed", bench_distributed.run),
        ("fig13_sensitivity", bench_sensitivity.run),
        ("fig14_turns", bench_turns.run),
        ("fig16_ablation", bench_ablation.run),
        ("table4_overhead", bench_overhead.run),
        ("table5_rollout", bench_rollout.run),
        ("beyond_memoryfulness", bench_memoryfulness.run),
        ("roofline", bench_roofline.run),
    ]
    print("name,us_per_call,derived")
    for name, fn in benches:
        t0 = time.time()
        try:
            fn(quick=quick)
            print(f"bench.{name}.wall_s,{time.time() - t0:.1f},ok")
        except Exception as e:  # keep the harness running
            print(f"bench.{name}.wall_s,{time.time() - t0:.1f},FAILED {e!r}")
            import traceback
            traceback.print_exc()


if __name__ == "__main__":
    main()
