"""Paper Fig. 10 (+Fig. 15): CPU-DRAM offloading variants (incl. Autellix+
= PLAS + LMCache), and the SSD tier extension."""
from benchmarks.common import emit, run_one, save_rows

DRAM = 200e9


def run(quick: bool = True) -> list[dict]:
    n = 40 if quick else 100
    rate = 0.06
    rows = []
    for policy in ("vllm", "autellix", "infercept", "continuum"):
        rows.append({**run_one(policy, n=n, rate=rate, offload=DRAM,
                               kv_budget=10e9), "tier": "dram"})
    # SSD extension (Fig. 15): smaller DRAM + SSD spillover
    for policy in ("vllm", "infercept", "continuum"):
        rows.append({**run_one(policy, n=n, rate=rate, offload=50e9, ssd=500e9,
                               kv_budget=10e9), "tier": "dram+ssd"})
    save_rows("fig10_offload", rows)
    v = next(r for r in rows if r["policy"] == "vllm" and r["tier"] == "dram")
    c = next(r for r in rows if r["policy"] == "continuum" and r["tier"] == "dram")
    i = next(r for r in rows if r["policy"] == "infercept" and r["tier"] == "dram")
    emit("fig10.jct_speedup_vs_vllm_offload", v["avg_jct"] / max(c["avg_jct"], 1e-9),
         f"continuum={c['avg_jct']:.0f}s infercept={i['avg_jct']:.0f}s")
    cs = next(r for r in rows if r["policy"] == "continuum" and r["tier"] == "dram+ssd")
    vs = next(r for r in rows if r["policy"] == "vllm" and r["tier"] == "dram+ssd")
    emit("fig15.ssd_jct_speedup_vs_vllm", vs["avg_jct"] / max(cs["avg_jct"], 1e-9),
         f"continuum={cs['avg_jct']:.0f}s")
    return rows


if __name__ == "__main__":
    run(quick=False)
