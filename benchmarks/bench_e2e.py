"""Paper Fig. 8: end-to-end JCT + throughput vs job rate, per policy,
on SWE-Bench and BFCL workloads."""
from benchmarks.common import POLICIES, emit, run_one, save_rows


def run(quick: bool = True) -> list[dict]:
    n = 40 if quick else 100
    rates = (0.04, 0.055, 0.07) if quick else (0.03, 0.04, 0.05, 0.06, 0.08)
    rows = []
    for workload in ("swe-bench", "bfcl"):
        for rate in rates:
            for policy in POLICIES:
                r = run_one(policy, workload=workload, n=n, rate=rate)
                rows.append(r)
    save_rows("fig8_e2e", rows)
    # headline: Continuum vs vLLM at the highest common rate
    for workload in ("swe-bench", "bfcl"):
        sub = [r for r in rows if r["workload"] == workload and
               r["rate"] == rates[-1]]
        v = next(r for r in sub if r["policy"] == "vllm")
        c = next(r for r in sub if r["policy"] == "continuum")
        emit(f"fig8.{workload}.jct_speedup_vs_vllm",
             v["avg_jct"] / max(c["avg_jct"], 1e-9),
             f"vllm={v['avg_jct']:.0f}s continuum={c['avg_jct']:.0f}s")
        emit(f"fig8.{workload}.throughput_gain_vs_vllm",
             c["throughput_jpm"] / max(v["throughput_jpm"], 1e-9),
             f"{c['throughput_jpm']:.2f} vs {v['throughput_jpm']:.2f} jobs/min")
    return rows


if __name__ == "__main__":
    run(quick=False)
