"""Paper Fig. 13: robustness across engine configs (max batch, chunk size)."""
from benchmarks.common import emit, run_one, save_rows


def run(quick: bool = True) -> list[dict]:
    n = 30 if quick else 80
    rows = []
    for mb in (16, 48, 96):
        for policy in ("vllm", "continuum"):
            rows.append({**run_one(policy, n=n, rate=0.05, max_batch=mb),
                         "knob": f"max_batch={mb}"})
    for cs in (256, 1024, 2048, 4096):
        for policy in ("vllm", "continuum"):
            rows.append({**run_one(policy, n=n, rate=0.05, chunk_size=cs),
                         "knob": f"chunk={cs}"})
    save_rows("fig13_sensitivity", rows)
    speedups = []
    for knob in {r["knob"] for r in rows}:
        v = next(r for r in rows if r["knob"] == knob and r["policy"] == "vllm")
        c = next(r for r in rows if r["knob"] == knob and r["policy"] == "continuum")
        speedups.append(v["avg_jct"] / max(c["avg_jct"], 1e-9))
    emit("fig13.min_speedup_across_configs", min(speedups),
         f"max={max(speedups):.2f} (stable across batch/chunk)")
    return rows


if __name__ == "__main__":
    run(quick=False)
