"""Batched paged decode: per-program loop vs one fused step per layer.

Sweeps decode batch size and measures tokens/s through
``PagedKVRuntime.decode_batch`` two ways — B sequential single-program
calls (the pre-batching execution shape) vs ONE batched call — plus the
cost model's analytic throughput curve for the same shape. Also asserts
the no-copy property of the fused step: its jaxpr contains no
dtype-conversion or transpose over a pool-shaped array (the kernels
consume the pools in their native layout; the old per-token decode cast
the whole pool once per layer per token).

Writes experiments/bench/decode.{csv,json}.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, save_rows

import jax                                                 # noqa: E402
import jax.numpy as jnp                                    # noqa: E402

from repro.configs import get_config                       # noqa: E402
from repro.serving.paged_runtime import PagedKVRuntime     # noqa: E402
from repro.serving.profiler import (CostModel, HardwareProfile,  # noqa: E402
                                    build_profile)

CONTEXT = 40                      # prefilled tokens per program
PAGE = 16


def _build(cfg, params_rng, B):
    rt = PagedKVRuntime(cfg, n_pages=max(64, 8 * B), page_size=PAGE)
    params = rt.model.init(params_rng)
    pids = []
    for i in range(B):
        pid = f"p{i}"
        toks = jax.random.randint(jax.random.PRNGKey(100 + i), (CONTEXT,),
                                  0, cfg.vocab_size)
        rt.prefill(params, pid, toks)
        pids.append(pid)
    return rt, params, pids


# ------------------------------------------------- no-copy jaxpr assertion
def _subjaxprs(v):
    if hasattr(v, "jaxpr") and hasattr(v, "consts"):       # ClosedJaxpr
        yield v.jaxpr
    elif hasattr(v, "eqns") and hasattr(v, "invars"):      # Jaxpr
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _subjaxprs(x)


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for p in eqn.params.values():
            for sub in _subjaxprs(p):
                yield from _iter_eqns(sub)


def assert_no_pool_copy(rt, params, B, n_tab) -> int:
    """Trace one fused decode step and assert no convert_element_type /
    transpose touches a pool-shaped operand anywhere in the (nested)
    jaxpr — the regression guard for the old O(pool) per-token casts.
    Returns the number of equations scanned."""
    toks = jnp.zeros((B,), jnp.int32)
    tables = jnp.zeros((B, n_tab), jnp.int32)
    lens = jnp.full((B,), CONTEXT, jnp.int32)
    app = jnp.arange(B, dtype=jnp.int32)
    offs = jnp.zeros((B,), jnp.int32)
    jaxpr = jax.make_jaxpr(rt._decode_step_impl)(
        params, rt.k_pages, rt.v_pages, toks, tables, lens, app, offs)
    pool_shape = tuple(rt.k_pages.shape)
    scanned, offenders = 0, []
    for eqn in _iter_eqns(jaxpr.jaxpr):
        scanned += 1
        if eqn.primitive.name in ("convert_element_type", "transpose"):
            for v in eqn.invars:
                shape = tuple(getattr(getattr(v, "aval", None), "shape", ()))
                if shape == pool_shape:
                    offenders.append(str(eqn))
    assert not offenders, \
        f"pool-shaped copy ops in the fused decode step: {offenders[:3]}"
    return scanned


# ------------------------------------------------------------------ bench
def run(quick: bool = True) -> list[dict]:
    cfg = get_config("glm4-9b", smoke=True)
    prof = build_profile(cfg, 1)
    cost = CostModel(prof, HardwareProfile())
    batches = (1, 2, 4, 8) if quick else (1, 2, 4, 8, 16, 32)
    steps = 3 if quick else 8
    rng = jax.random.PRNGKey(0)
    rows = []

    # the no-copy guard, once (shape-independent property of the trace)
    rt0, params0, _ = _build(cfg, rng, 2)
    n_eqns = assert_no_pool_copy(rt0, params0, 2, 4)
    emit("decode.no_pool_copy.eqns_scanned", float(n_eqns), "ok")

    repeats = 3
    for B in batches:
        # best-of-N timing windows per mode: a loaded host inflates any
        # single window, and the gate compares two measured quantities
        rt, params, pids = _build(cfg, rng, B)
        rt.decode_batch(params, pids)                       # compile
        batched_s = float("inf")
        for _ in range(repeats):
            t0 = time.time()
            for _ in range(steps):
                jax.block_until_ready(rt.decode_batch(params, pids))
            batched_s = min(batched_s, time.time() - t0)

        rt, params, pids = _build(cfg, rng, B)
        rt.decode(params, pids[0])                          # compile B=1
        seq_s = float("inf")
        for _ in range(repeats):
            t0 = time.time()
            for _ in range(steps):
                for pid in pids:
                    jax.block_until_ready(rt.decode(params, pid))
            seq_s = min(seq_s, time.time() - t0)

        n_tok = B * steps
        row = {"batch": B, "context": CONTEXT, "steps": steps,
               "batched_tok_s": n_tok / batched_s,
               "sequential_tok_s": n_tok / seq_s,
               "speedup": seq_s / batched_s,
               "analytic_tok_s": cost.decode_tokens_per_s(B, CONTEXT)}
        rows.append(row)
        emit(f"decode.batched_tok_s.b{B}", row["batched_tok_s"],
             f"speedup {row['speedup']:.2f}x vs per-program loop")

    big = [r for r in rows if r["batch"] >= 8]
    if big:
        worst = min(r["speedup"] for r in big)
        emit("decode.speedup_at_b8plus", worst,
             "PASS >=2x" if worst >= 2.0 else "FAIL <2x")
    save_rows("decode", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
