"""Paper Fig. 14: scaling law for turn numbers (repeat trace 1-5x with
inversely scaled token lengths)."""
from benchmarks.common import emit, run_one, save_rows


def run(quick: bool = True) -> list[dict]:
    n = 30 if quick else 80
    rows = []
    scales = (1.0, 2.0, 3.0) if quick else (1.0, 2.0, 3.0, 4.0, 5.0)
    for ts in scales:
        for policy in ("vllm", "infercept", "continuum"):
            rows.append({**run_one(policy, n=n, rate=0.05, offload=200e9,
                                   kv_budget=10e9, turn_scale=ts),
                         "turn_scale": ts})
    save_rows("fig14_turns", rows)
    lo = [r for r in rows if r["turn_scale"] == scales[0]]
    hi = [r for r in rows if r["turn_scale"] == scales[-1]]
    for policy in ("vllm", "continuum"):
        l = next(r for r in lo if r["policy"] == policy)
        h = next(r for r in hi if r["policy"] == policy)
        emit(f"fig14.{policy}.jct_growth_{int(scales[-1])}x_turns",
             h["avg_jct"] / max(l["avg_jct"], 1e-9),
             f"{l['avg_jct']:.0f}s -> {h['avg_jct']:.0f}s")
    return rows


if __name__ == "__main__":
    run(quick=False)
