"""Elastic fleet vs static provisioning under diurnal + bursty load.

A static fleet has to pick one size: provision for the peak and the
trough burns idle replica-hours; provision for the trough and the peak
melts.  This bench runs the same seeded diurnal+bursty workload
(``generate_diurnal_programs``: sinusoidal arrival rate with burst
cohorts riding on top) through three fleets:

    auto        starts at a single decode replica; a hysteretic
                ``ScalingPolicy`` grows/drains the fleet at runtime
                from queue-ETA + block-pool pressure, adding (and
                later draining) one prefill-only replica plus up to
                3 decode replicas
    static3     3 decode replicas, fixed for the whole run
    static2     2 decode replicas, fixed for the whole run

All fleets use the ``kv_aware_migrate`` router so the only variable is
provisioning.  Emits ``experiments/bench/elastic.csv`` with mean/p90
JCT, queueing delay and replica-hours (``Cluster.replica_seconds``).
The acceptance bar for the subsystem: the autoscaled fleet beats
static3 on replica-hours at equal-or-better mean JCT, and beats
static2 on mean JCT.
"""
from __future__ import annotations

import time

from benchmarks.common import RESULTS_DIR, emit, save_rows  # noqa: F401
from repro.configs import get_config
from repro.serving.cluster import ClusterConfig, ScalingConfig, build_cluster
from repro.serving.engine import EngineConfig
from repro.serving.offload import OffloadConfig
from repro.serving.prefix import PrefixConfig
from repro.serving.profiler import HardwareProfile
from repro.sim.workload import WORKLOADS, generate_diurnal_programs

FLEETS = ("auto", "static3", "static2")


def elastic_workload(*, workload="swe-bench", n=36, rate=0.003, seed=0,
                     period_s=1200.0, peak_mult=12.0):
    """One diurnal period: sparse trough at both ends, bursty peak in
    the middle — the shape where a fixed fleet size must be wrong at
    least half the time.  The trough rate keeps a single replica under
    ~50% busy; the 12x peak (plus burst cohorts) needs 3-4."""
    spec = WORKLOADS[workload]
    return generate_diurnal_programs(
        spec, n=n, rate_jps=rate, seed=seed, period_s=period_s,
        peak_mult=peak_mult, burst_frac=0.3, burst_size=3,
        burst_span_s=1.0, tenants=4, tenant_skew=1.6, share_ratio=0.2,
        storm_frac=0.3, storm_gap_s=20.0, churn_frac=0.3)


def run_fleet(fleet: str, programs, *, arch="glm4-9b", chips=4,
              kv_budget=8e9, max_batch=12, chunk_size=2048,
              dram=60e9, ssd=120e9, peer_bw=50e9) -> dict:
    arch_cfg = get_config(arch)
    ecfg = EngineConfig(
        policy="continuum", chips=chips, kv_budget_bytes=kv_budget,
        max_batch=max_batch, chunk_size=chunk_size,
        offload=OffloadConfig(dram_bytes=dram, ssd_bytes=ssd),
        prefix=PrefixConfig())
    if fleet == "auto":
        ccfg = ClusterConfig(
            n_replicas=1, router="kv_aware_migrate", peer_bw=peer_bw,
            peer_latency_s=0.001, migrate_min_gain_s=0.5,
            scaling=ScalingConfig(min_replicas=1, max_replicas=3,
                                  scale_up_eta_s=20.0, scale_down_eta_s=3.0,
                                  pool_pressure=0.9, up_hold_s=5.0,
                                  down_hold_s=25.0, cooldown_s=15.0,
                                  prefill_max=1))
    else:
        ccfg = ClusterConfig(
            n_replicas=int(fleet[-1]), router="kv_aware_migrate",
            peer_bw=peer_bw, peer_latency_s=0.001, migrate_min_gain_s=0.5)
    cluster = build_cluster(arch_cfg, ecfg, ccfg, HardwareProfile())
    t0 = time.time()
    s = cluster.run(programs, max_seconds=1e7)
    wall = time.time() - t0
    end = cluster.clock.now
    cluster.check(end)                   # conservation holds at the end
    return {"fleet": fleet, "n": len(programs),
            "avg_jct": s.avg_jct, "p50": s.p50_jct, "p90": s.p90_jct,
            "queueing": s.avg_queueing, "ttft": s.avg_ttft,
            "makespan_s": end,
            "replica_hours": cluster.replica_seconds(end) / 3600.0,
            "scale_ups": cluster.stats.scale_ups,
            "scale_downs": cluster.stats.scale_downs,
            "retired": cluster.stats.retired,
            "prefill_handoffs": cluster.stats.prefill_handoffs,
            "migrations": cluster.stats.migrations,
            "cold_rehomes": cluster.stats.cold_rehomes,
            "drained_tokens": cluster.stats.drained_tokens,
            "wall_s": wall}


def run(quick: bool = True) -> list[dict]:
    n = 40 if quick else 96
    seeds = (0,) if quick else (0, 1, 2)
    rows = []
    for seed in seeds:
        programs = elastic_workload(n=n, seed=seed)
        for fleet in FLEETS:
            row = run_fleet(fleet, programs)
            row["seed"] = seed
            rows.append(row)
            emit(f"elastic.{fleet}.avg_jct_s.seed{seed}", row["avg_jct"],
                 f"rh={row['replica_hours']:.3f},"
                 f"ups={row['scale_ups']},downs={row['scale_downs']}")
    save_rows("elastic", rows)
    base = {r["fleet"]: r for r in rows if r["seed"] == seeds[0]}
    auto, s3, s2 = base["auto"], base["static3"], base["static2"]
    emit("elastic.auto_vs_static3.replica_hour_savings",
         1.0 - auto["replica_hours"] / max(s3["replica_hours"], 1e-9))
    emit("elastic.auto_vs_static3.jct_ratio",
         auto["avg_jct"] / max(s3["avg_jct"], 1e-9))
    emit("elastic.auto_vs_static2.jct_speedup",
         s2["avg_jct"] / max(auto["avg_jct"], 1e-9))
    ok = (auto["replica_hours"] < s3["replica_hours"]
          and auto["avg_jct"] <= s3["avg_jct"] * 1.001
          and auto["avg_jct"] < s2["avg_jct"])
    print(f"elastic acceptance bar: {'PASS' if ok else 'FAIL'} "
          f"(auto jct={auto['avg_jct']:.2f}s rh={auto['replica_hours']:.3f} "
          f"| static3 jct={s3['avg_jct']:.2f}s rh={s3['replica_hours']:.3f} "
          f"| static2 jct={s2['avg_jct']:.2f}s)")
    return rows


if __name__ == "__main__":
    run()
