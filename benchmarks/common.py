"""Shared benchmark utilities: policy-grid runs over agent workloads."""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config                      # noqa: E402
from repro.serving.engine import Engine, EngineConfig     # noqa: E402
from repro.serving.offload import OffloadConfig           # noqa: E402
from repro.serving.prefix import PrefixConfig             # noqa: E402
from repro.serving.profiler import HardwareProfile        # noqa: E402
from repro.sim.runner import run_workload                 # noqa: E402
from repro.sim.workload import WORKLOADS, generate_programs  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[1] / "experiments" / "bench"

# paper-like single-host serving footprint: the KV pool is the contended
# resource (Llama-8B on one A100/H100 ~ 40-60 GB of KV)
DEFAULT = dict(arch="glm4-9b", chips=8, kv_budget=40e9, max_batch=48,
               chunk_size=2048)

POLICIES = ("vllm", "autellix", "infercept", "continuum")
ABLATIONS = ("vllm", "fcfs_program", "static_ttl", "continuum")


def run_one(policy: str, *, workload="swe-bench", n=60, rate=0.05, seed=0,
            offload=None, ssd=0.0, arch=None, chips=None, kv_budget=None,
            max_batch=None, chunk_size=None, turn_scale=1.0,
            scheduler_overhead_s=0.0, n_engines=1, router_policy="session",
            prefix=False, share_ratio=0.0, prefix_groups=1):
    arch_cfg = get_config(arch or DEFAULT["arch"])
    spec = WORKLOADS[workload]
    programs = generate_programs(spec, n=n, rate_jps=rate, seed=seed,
                                 turn_scale=turn_scale,
                                 share_ratio=share_ratio,
                                 prefix_groups=prefix_groups)
    off = None
    if offload:
        off = OffloadConfig(dram_bytes=offload, ssd_bytes=ssd)
    engines = []
    for i in range(n_engines):
        ecfg = EngineConfig(
            policy=policy, chips=chips or DEFAULT["chips"], offload=off,
            max_batch=max_batch or DEFAULT["max_batch"],
            chunk_size=chunk_size or DEFAULT["chunk_size"],
            kv_budget_bytes=kv_budget or DEFAULT["kv_budget"],
            scheduler_overhead_s=scheduler_overhead_s,
            prefix=PrefixConfig() if prefix else None)
        engines.append(Engine(arch_cfg, ecfg, HardwareProfile(),
                              engine_id=f"e{i}"))
    from repro.serving.router import Router
    router = Router(engines, policy=router_policy)
    t0 = time.time()
    summary = run_workload(programs, engines, router, max_seconds=1e7)
    wall = time.time() - t0
    stats = engines[0].scheduler.stats
    return {"policy": policy, "workload": workload, "rate": rate,
            "avg_jct": summary.avg_jct, "p50": summary.p50_jct,
            "p90": summary.p90_jct, "p95": summary.p95_jct,
            "throughput_jpm": summary.throughput_jobs_per_s * 60,
            "tok_per_s": summary.throughput_tokens_per_s,
            "queueing": summary.avg_queueing,
            "ttft": summary.avg_ttft,
            "ttl_hit_rate": summary.avg_ttl_hit_rate,
            "prefill_tokens": summary.prefill_tokens,
            "prefix_hit_tokens": summary.prefix_hit_tokens,
            "pins": stats.pins, "hits": stats.ttl_hits,
            "expiries": stats.ttl_expiries,
            "evictions": stats.deadlock_evictions,
            "preemptions": stats.preemptions,
            "prefix_hits": sum(e.scheduler.stats.prefix_hits
                               for e in engines),
            # tiered-kvstore counters (all 0 when offload is disabled)
            "demotions": stats.demotions,
            "reloads": stats.offload_reloads,
            "full_recomputes": stats.full_recomputes,
            "reload_s": stats.reload_seconds,
            "recompute_s": stats.recompute_seconds,
            "h2d_gb": (engines[0].kvstore.transfer.h2d.bytes_moved / 1e9
                       if engines[0].kvstore is not None else 0.0),
            "wall_s": wall}


def save_rows(name: str, rows: list[dict]) -> Path:
    """Write a bench result as both ``<name>.csv`` (plots, eyeballs) and
    ``<name>.json`` (tooling: typed values, stable key order) under
    experiments/bench/."""
    import csv
    import json
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / f"{name}.csv"
    if rows:
        fields = list(dict.fromkeys(k for r in rows for k in r))
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=fields, restval="")
            w.writeheader()
            w.writerows(rows)
        (RESULTS_DIR / f"{name}.json").write_text(
            json.dumps({"bench": name, "rows": rows},
                       indent=2, sort_keys=True) + "\n")
    return path


def emit(name: str, value: float, derived: str = "") -> None:
    """benchmarks.run contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{value:.3f},{derived}")
