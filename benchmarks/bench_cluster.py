"""Multi-replica cluster serving: routing-policy grid under skewed load.

The cluster question is *placement*: a program returning from a tool
call may find its home replica congested while a peer is idle but cold.
This bench runs the same skewed multi-tenant workload (hot-tenant Zipf
skew + tool-storm bursts + affinity churn —
``generate_skewed_programs``) through a >=3-replica cluster under four
routers:

    round_robin        scatter turns, KV dropped at every re-home
    sticky             session affinity, never moves (legacy Router)
    kv_aware           cost-scored placement, re-homes recompute cold
    kv_aware_migrate   re-homes ship the KV over the PeerLink when the
                       TTL cost model says that beats recomputing

Emits ``experiments/bench/cluster.csv`` with mean/p90 JCT, queueing,
migration counts and per-policy tier traffic. The acceptance bar for
the subsystem: ``kv_aware_migrate`` beats BOTH ``round_robin`` and
``sticky`` on mean JCT in the skewed scenario.
"""
from __future__ import annotations

import time

from benchmarks.common import RESULTS_DIR, emit, save_rows  # noqa: F401
from repro.configs import get_config
from repro.serving.cluster import ClusterConfig, build_cluster
from repro.serving.engine import EngineConfig
from repro.serving.offload import OffloadConfig
from repro.serving.prefix import PrefixConfig
from repro.serving.profiler import HardwareProfile
from repro.sim.workload import WORKLOADS, generate_skewed_programs

ROUTERS = ("round_robin", "sticky", "kv_aware", "kv_aware_migrate")


def run_cluster_once(router: str, *, workload="swe-bench", n=24, rate=2.0,
                     seed=0, replicas=3, arch="glm4-9b", chips=4,
                     kv_budget=8e9, max_batch=12, chunk_size=2048,
                     dram=60e9, ssd=120e9, peer_bw=50e9,
                     tenants=4, tenant_skew=2.0, storm_frac=0.6,
                     storm_gap_s=25.0, churn_frac=0.5,
                     migrate_min_gain_s=0.5) -> dict:
    arch_cfg = get_config(arch)
    spec = WORKLOADS[workload]
    programs = generate_skewed_programs(
        spec, n=n, rate_jps=rate, seed=seed, tenants=tenants,
        tenant_skew=tenant_skew, share_ratio=0.15, storm_frac=storm_frac,
        storm_gap_s=storm_gap_s, churn_frac=churn_frac)
    ecfg = EngineConfig(
        policy="continuum", chips=chips, kv_budget_bytes=kv_budget,
        max_batch=max_batch, chunk_size=chunk_size,
        offload=OffloadConfig(dram_bytes=dram, ssd_bytes=ssd),
        prefix=PrefixConfig())
    ccfg = ClusterConfig(n_replicas=replicas, router=router,
                         peer_bw=peer_bw, peer_latency_s=0.001,
                         migrate_min_gain_s=migrate_min_gain_s)
    cluster = build_cluster(arch_cfg, ecfg, ccfg, HardwareProfile())
    t0 = time.time()
    s = cluster.run(programs, max_seconds=1e7)
    wall = time.time() - t0
    cluster.check(cluster.clock.now)     # conservation holds at the end
    peer_gb = sum(l.bytes_moved for l in cluster.links.values()) / 1e9
    return {"router": router, "replicas": replicas, "workload": workload,
            "n": n, "rate": rate, "seed": seed,
            "avg_jct": s.avg_jct, "p50": s.p50_jct, "p90": s.p90_jct,
            "p99": s.p99_jct, "queueing": s.avg_queueing, "ttft": s.avg_ttft,
            "throughput_jpm": s.throughput_jobs_per_s * 60,
            "ttl_hit_rate": s.avg_ttl_hit_rate,
            "migrations": cluster.stats.migrations,
            "migrated_tokens": cluster.stats.migrated_tokens,
            "migration_denied": cluster.stats.migration_denied,
            "cold_rehomes": cluster.stats.cold_rehomes,
            "peer_gb": peer_gb,
            "reloads": sum(e.scheduler.stats.offload_reloads
                           for e in cluster.engines),
            "full_recomputes": sum(e.scheduler.stats.full_recomputes
                                   for e in cluster.engines),
            "preemptions": sum(e.scheduler.stats.preemptions
                               for e in cluster.engines),
            "wall_s": wall}


def run(quick: bool = True) -> list[dict]:
    n = 24 if quick else 72
    seeds = (0,) if quick else (0, 1, 2)
    rows = []
    for seed in seeds:
        for router in ROUTERS:
            row = run_cluster_once(router, n=n, seed=seed)
            rows.append(row)
            emit(f"cluster.{router}.avg_jct_s.seed{seed}",
                 row["avg_jct"],
                 f"mig={row['migrations']},cold={row['cold_rehomes']}")
    save_rows("cluster", rows)
    base = {r["router"]: r for r in rows if r["seed"] == seeds[0]}
    mig = base["kv_aware_migrate"]["avg_jct"]
    emit("cluster.migrate_vs_round_robin.speedup",
         base["round_robin"]["avg_jct"] / max(mig, 1e-9))
    emit("cluster.migrate_vs_sticky.speedup",
         base["sticky"]["avg_jct"] / max(mig, 1e-9))
    return rows


if __name__ == "__main__":
    run()
