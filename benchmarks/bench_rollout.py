"""Paper Table 5: RL-rollout micro-benchmark — inference steps/minute for
OpenHands-style rollouts on a single engine (8-chip node)."""
from benchmarks.common import emit, run_one, save_rows


def run(quick: bool = True) -> list[dict]:
    n = 40 if quick else 100
    rows = []
    for policy in ("vllm", "continuum"):
        r = run_one(policy, workload="openhands", n=n, rate=0.12,
                    kv_budget=40e9)
        # steps/min = LLM turns completed per minute of makespan
        r["steps_per_min"] = r["throughput_jpm"] * 20.0   # ~20 turns/program
        rows.append(r)
    save_rows("table5_rollout", rows)
    v, c = rows[0], rows[1]
    emit("table5.rollout_steps_per_min_gain",
         c["steps_per_min"] / max(v["steps_per_min"], 1e-9),
         f"vllm={v['steps_per_min']:.1f} continuum={c['steps_per_min']:.1f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
