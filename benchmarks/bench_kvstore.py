"""Tiered KV store sweep: TTL-expiry demotion (HBM→DRAM→SSD) vs dropping.

Baseline is `continuum` with no offload tier: a TTL expiry *drops* the
context and the program's next turn pays a full prefill recompute. The
sweep runs the same workload with the tiered store enabled at increasing
DRAM capacities (plus one DRAM+SSD spillover point): expiries *demote*
to host DRAM instead (async D2H on the transfer timeline) and the next
turn reloads, with reload seconds priced by the `TransferEngine` against
in-flight transfer state. Reported per row: mean/tail JCT, tier-hit
ratio (reloads / context-restoration events), and the reload-vs-recompute
seconds actually paid.
"""
from benchmarks.common import emit, run_one, save_rows

KV_BUDGET = 10e9          # contended HBM pool: expiries actually happen
DRAM_SWEEP = (1e9, 2e9, 5e9, 10e9, 25e9)   # pressure → comfortable


def _row(policy, dram, ssd, **kw):
    r = run_one(policy, offload=dram or None, ssd=ssd,
                kv_budget=KV_BUDGET, **kw)
    restored = r["reloads"] + r["full_recomputes"]
    return {**r, "dram_gb": dram / 1e9, "ssd_gb": ssd / 1e9,
            "tier_hit": r["reloads"] / restored if restored else 0.0}


def run(quick: bool = True) -> list[dict]:
    n = 40 if quick else 100
    kw = dict(n=n, rate=0.06)
    rows = [_row("continuum", 0.0, 0.0, **kw)]            # drop-on-expiry
    for dram in DRAM_SWEEP:
        rows.append(_row("continuum", dram, 0.0, **kw))
    rows.append(_row("continuum", 2e9, 50e9, **kw))       # SSD spillover
    save_rows("kvstore", rows)

    base = rows[0]
    best = min(rows[1:], key=lambda r: r["avg_jct"])
    emit("kvstore.jct_speedup_vs_no_offload",
         base["avg_jct"] / max(best["avg_jct"], 1e-9),
         f"no_offload={base['avg_jct']:.0f}s "
         f"dram{best['dram_gb']:.0f}+ssd{best['ssd_gb']:.0f}="
         f"{best['avg_jct']:.0f}s")
    emit("kvstore.tier_hit_ratio", best["tier_hit"],
         f"reloads={best['reloads']} recomputes={best['full_recomputes']} "
         f"demotions={best['demotions']}")
    emit("kvstore.reload_vs_recompute_s", best["reload_s"],
         f"reload={best['reload_s']:.1f}s (TransferEngine) vs "
         f"recompute_paid={best['recompute_s']:.1f}s "
         f"baseline_recompute={base['recompute_s']:.1f}s "
         f"h2d={best['h2d_gb']:.1f}GB")
    return rows


if __name__ == "__main__":
    run(quick=False)
