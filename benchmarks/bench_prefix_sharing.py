"""Cross-program shared-prefix KV: prefill tokens saved, JCT and TTFT vs
share ratio, for three stacks:

  baseline          — vLLM semantics (no retention, no prefix cache)
  continuum         — TTL pinning only (the paper's system)
  continuum+prefix  — TTL pinning + the radix shared-prefix subsystem

Sweeps the fraction of each program's tokens that is a fleet-shared agent
preamble (system prompt + tool schemas). The headline emits the prefill
reduction and JCT gain of continuum+prefix over continuum at share 0.3
(acceptance: >=30% fewer prefill tokens, lower mean JCT).
"""
from benchmarks.common import emit, run_one, save_rows

CONFIGS = (
    ("baseline", dict(policy="vllm", prefix=False)),
    ("continuum", dict(policy="continuum", prefix=False)),
    ("continuum+prefix", dict(policy="continuum", prefix=True)),
)


def run(quick: bool = True) -> list[dict]:
    n = 24 if quick else 60
    rate = 0.06
    kv = 20e9
    ratios = (0.15, 0.3) if quick else (0.0, 0.15, 0.3, 0.5)
    rows = []
    for ratio in ratios:
        for name, kw in CONFIGS:
            r = run_one(kw["policy"], workload="swe-bench", n=n, rate=rate,
                        kv_budget=kv, prefix=kw["prefix"], share_ratio=ratio)
            r["config"] = name
            r["share_ratio"] = ratio
            rows.append(r)
    # fleet scenario: 4 engines x 4 agent templates — prefix-affinity
    # routing co-locates each template's sessions where its preamble lives
    for router in ("session", "prefix_affinity"):
        r = run_one("continuum", workload="swe-bench", n=max(32, n),
                    rate=0.15, kv_budget=kv, prefix=True, share_ratio=0.3,
                    prefix_groups=4, n_engines=4, router_policy=router)
        r["config"] = f"fleet-continuum+prefix/{router}"
        r["share_ratio"] = 0.3
        rows.append(r)
    save_rows("prefix_sharing", rows)

    ratio = 0.3
    sub = {r["config"]: r for r in rows
           if r.get("share_ratio") == ratio and "fleet" not in r["config"]}
    cont, pref = sub["continuum"], sub["continuum+prefix"]
    reduction = 1 - pref["prefill_tokens"] / max(cont["prefill_tokens"], 1)
    emit("prefix.share0.3.prefill_reduction_pct", 100 * reduction,
         f"{cont['prefill_tokens']:.0f} -> {pref['prefill_tokens']:.0f} tokens")
    emit("prefix.share0.3.jct_speedup_vs_continuum",
         cont["avg_jct"] / max(pref["avg_jct"], 1e-9),
         f"continuum={cont['avg_jct']:.0f}s +prefix={pref['avg_jct']:.0f}s")
    emit("prefix.share0.3.ttft_speedup_vs_continuum",
         cont["ttft"] / max(pref["ttft"], 1e-9),
         f"continuum={cont['ttft']:.2f}s +prefix={pref['ttft']:.2f}s")
    affin = {r["config"]: r for r in rows if "fleet" in r["config"]}
    sess = affin["fleet-continuum+prefix/session"]
    paff = affin["fleet-continuum+prefix/prefix_affinity"]
    emit("prefix.router_affinity.prefill_saving_vs_session",
         sess["prefill_tokens"] / max(paff["prefill_tokens"], 1e-9),
         f"session={sess['prefill_tokens']:.0f} "
         f"affinity={paff['prefill_tokens']:.0f} tokens")
    return rows


if __name__ == "__main__":
    run(quick=False)
