"""Paper Fig. 11: P90/P95 tail latency (per-turn queueing delay shows up in
the tail first)."""
from benchmarks.common import POLICIES, emit, run_one, save_rows


def run(quick: bool = True) -> list[dict]:
    n = 50 if quick else 120
    rows = [run_one(p, n=n, rate=0.06, offload=200e9, kv_budget=10e9)
            for p in POLICIES]
    save_rows("fig11_tail", rows)
    v = next(r for r in rows if r["policy"] == "vllm")
    c = next(r for r in rows if r["policy"] == "continuum")
    emit("fig11.p95_speedup_vs_vllm", v["p95"] / max(c["p95"], 1e-9),
         f"p95 vllm={v['p95']:.0f}s continuum={c['p95']:.0f}s")
    emit("fig11.p90_speedup_vs_vllm", v["p90"] / max(c["p90"], 1e-9), "")
    return rows


if __name__ == "__main__":
    run(quick=False)
