"""Prices the critical-path JCT attribution pass (repro.obs.attribution)
over a seeded cluster trace: wall time per analysis, events scanned per
second, and the sums-to-JCT verdict. Seeds the CI artifact
``experiments/bench/BENCH_attribution.json`` so the attribution job can
diff the analysis cost across commits — the pass is offline (a scrape of
``/attribution``), so the bound here is operator patience, not the <3%
scheduler hot-path gate (which bench_overhead owns, drift included)."""
import json
import time

from benchmarks.common import RESULTS_DIR, emit, save_rows

import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.sim.replay import ReplayConfig, cluster_programs, run_cluster_trace


def run(seed: int = 0, n_programs: int = 16, iters: int = 5) -> dict:
    rc = ReplayConfig()
    programs = cluster_programs(seed, n=n_programs, rate_jps=3.0)
    _, violations, cluster = run_cluster_trace(
        programs, rc, replicas=3, router="kv_aware_migrate",
        telemetry=True, drift=True)
    tel = cluster.obs
    events = len(tel.trace)
    # analysis is a pure function of the trace: time it repeatedly on the
    # same events and keep the best (the offline floor, noise excluded)
    best = float("inf")
    report = None
    for _ in range(iters):
        t0 = time.perf_counter()
        report = tel.attribution()
        best = min(best, time.perf_counter() - t0)
    fleet = report["fleet"]
    row = {"seed": seed, "programs": fleet["n_programs"],
           "trace_events": events,
           "analysis_ms": round(best * 1000.0, 3),
           "events_per_s": round(events / best, 1) if best else 0.0,
           "sums_to_jct": report["ok"],
           "violations": len(violations),
           "top_component": (fleet["bottlenecks"][0]["component"]
                             if fleet["bottlenecks"] else ""),
           "ok": report["ok"] and not violations}
    save_rows("attribution", [row])
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / "BENCH_attribution.json").write_text(
        json.dumps(row, indent=2, sort_keys=True) + "\n")
    emit("attribution.analysis_ms", row["analysis_ms"],
         f"{row['programs']} programs, {events} events, "
         f"sums_to_jct={'ok' if report['ok'] else 'FAIL'}")
    return row


if __name__ == "__main__":
    sys.exit(0 if run()["ok"] else 1)
