"""Distributed serving (paper §6.2): a fleet of engines behind the
session-aware router, vs round-robin; includes straggler mitigation by
migration.

    PYTHONPATH=src python examples/distributed_router.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.serving.engine import Engine, EngineConfig
from repro.serving.offload import OffloadConfig
from repro.serving.profiler import HardwareProfile
from repro.serving.router import Router
from repro.sim.runner import run_workload
from repro.sim.workload import SWE_BENCH, generate_programs


def fleet(n, policy):
    arch = get_config("glm4-9b")
    return [Engine(arch, EngineConfig(policy=policy, chips=8,
                                      offload=OffloadConfig(dram_bytes=200e9),
                                      max_batch=48, kv_budget_bytes=40e9),
                   HardwareProfile(), engine_id=f"e{i}") for i in range(n)]


def main():
    n, rate = 80, 0.2                                 # 4-engine fleet load
    print(f"{'setup':<36}{'avg JCT':>10}{'p95':>10}{'TTL hits':>9}")
    for label, policy, router_policy, thresh in (
            ("vLLM + round-robin", "vllm", "round_robin", 0.0),
            ("Continuum + round-robin", "continuum", "round_robin", 0.0),
            ("Continuum + session-aware", "continuum", "session", 0.0),
            ("Continuum + session + migration", "continuum", "session", 3.0)):
        engines = fleet(4, policy)
        router = Router(engines, policy=router_policy,
                        migrate_threshold=thresh)
        programs = generate_programs(SWE_BENCH, n=n, rate_jps=rate, seed=0)
        s = run_workload(programs, engines, router, max_seconds=1e7)
        hits = sum(e.scheduler.stats.ttl_hits for e in engines)
        extra = f"  (migrations={router.migrations})" if thresh else ""
        print(f"{label:<36}{s.avg_jct:>9.1f}s{s.p95_jct:>9.1f}s{hits:>9}"
              f"{extra}")


if __name__ == "__main__":
    main()
