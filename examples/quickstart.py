"""Quickstart: REAL end-to-end agent serving on CPU.

A small transformer actually generates tokens through the Continuum engine
(continuous batching + chunked prefill + TTL pinning); tool calls pause
programs and their KV caches are pinned with computed TTLs, so returning
turns skip prefill. Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.configs import get_config
from repro.serving.backend import JaxModelBackend
from repro.serving.engine import Engine, EngineConfig
from repro.serving.profiler import HardwareProfile
from repro.sim.runner import run_workload
from repro.sim.workload import WorkloadSpec, generate_programs


def main():
    cfg = get_config("stablelm-3b", smoke=True)         # ~1M params, CPU-fast
    backend = JaxModelBackend(cfg, rng=jax.random.PRNGKey(0), max_len=512)

    # a small, quick agent workload (short contexts fit the smoke model)
    spec = WorkloadSpec(
        name="demo", mean_turns=3, std_turns=1, tool_mean_s=0.3,
        tool_std_s=0.3, tokens_mean=360, tokens_std=60, output_frac=0.2,
        max_context=512,
        tools=(("ls", 0.5, 0.05, 0.4), ("pytest", 0.5, 0.3, 0.6)))
    programs = generate_programs(spec, n=4, rate_jps=2.0, seed=0)

    ecfg = EngineConfig(policy="continuum", chips=1, max_batch=8,
                        chunk_size=128, kv_budget_bytes=2e6,
                        ttl=__import__("repro.core.ttl",
                                       fromlist=["TTLConfig"]).TTLConfig(
                            cold_start_k=0, exp_unit_mean=0.2))
    eng = Engine(cfg, ecfg, HardwareProfile(), backend=backend)

    print(f"serving {len(programs)} agent programs "
          f"({sum(p.num_turns for p in programs)} turns) with REAL "
          f"generation on CPU ...")
    s = run_workload(programs, [eng], max_seconds=3600)
    st = eng.scheduler.stats
    total_prompt = sum(p.context_len_at(i) for p in programs
                       for i in range(p.num_turns))
    print(f"done: {s.n_programs} programs, avg JCT {s.avg_jct:.2f}s "
          f"(wall-clock, real model steps)")
    print(f"TTL: {st.pins} pins, {st.ttl_hits} hits, {st.ttl_expiries} "
          f"expiries")
    print(f"prefill tokens actually computed: "
          f"{backend.prefill_tokens_computed} / {total_prompt} naive "
          f"(saved {1 - backend.prefill_tokens_computed / total_prompt:.0%} "
          f"via TTL pinning + cache continuity)")
    print(f"decode tokens generated: {backend.decode_tokens_computed}")


if __name__ == "__main__":
    main()
