"""Train a small LM end to end: data pipeline -> sharded train step ->
AdamW -> checkpoint/restart. Demonstrates the training substrate used by
the RL-rollout path (paper §6.4).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--d-model 256]
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import make_host_mesh
from repro.train import optimizer as opt_mod
from repro.train.train_loop import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="demo-lm", family="dense", num_layers=args.layers,
        d_model=args.d_model, num_heads=args.d_model // 64 or 2,
        num_kv_heads=args.d_model // 64 or 2, d_ff=args.d_model * 3,
        vocab_size=4096, max_seq_len=args.seq)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")
    mesh = make_host_mesh()
    shape = ShapeSpec("demo", "train", args.seq, args.batch)
    tcfg = TrainConfig(steps=args.steps, ckpt_every=50,
                       ckpt_dir="/tmp/repro_train_lm", log_every=20,
                       adamw=opt_mod.AdamWConfig(lr=1e-3, warmup_steps=20,
                                                 total_steps=args.steps))
    tr = Trainer(cfg, mesh, shape, tcfg)
    if args.resume and tr.resume():
        print(f"resumed from step {tr.step}")
    hist = tr.run()
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over "
          f"{len(hist)} steps; checkpoints in /tmp/repro_train_lm")


if __name__ == "__main__":
    main()
