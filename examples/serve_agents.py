"""The paper's main experiment (Fig. 8) as a runnable example: replay an
agentic trace against every scheduling policy and compare JCT/throughput.

    PYTHONPATH=src python examples/serve_agents.py [--workload bfcl]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.serving.engine import Engine, EngineConfig
from repro.serving.offload import OffloadConfig
from repro.serving.profiler import HardwareProfile
from repro.sim.runner import run_workload
from repro.sim.workload import WORKLOADS, generate_programs, save_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="swe-bench", choices=list(WORKLOADS))
    ap.add_argument("-n", type=int, default=60)
    ap.add_argument("--rate", type=float, default=0.055)
    ap.add_argument("--offload-gb", type=float, default=0.0)
    args = ap.parse_args()

    arch = get_config("glm4-9b")
    programs = generate_programs(WORKLOADS[args.workload], n=args.n,
                                 rate_jps=args.rate, seed=0)
    save_trace(programs, "/tmp/agent_trace.json")
    print(f"trace: {len(programs)} programs, "
          f"{sum(p.num_turns for p in programs)} turns "
          f"(saved to /tmp/agent_trace.json)")
    off = OffloadConfig(dram_bytes=args.offload_gb * 1e9) \
        if args.offload_gb else None

    print(f"{'policy':<14}{'avg JCT':>10}{'p95':>10}{'jobs/min':>10}"
          f"{'queueing':>10}{'TTL hits':>9}")
    results = {}
    for policy in ("vllm", "autellix", "infercept", "static_ttl", "continuum"):
        eng = Engine(arch, EngineConfig(policy=policy, chips=8, offload=off,
                                        max_batch=48, chunk_size=2048,
                                        kv_budget_bytes=40e9),
                     HardwareProfile())
        programs = generate_programs(WORKLOADS[args.workload], n=args.n,
                                     rate_jps=args.rate, seed=0)
        s = run_workload(programs, [eng], max_seconds=1e7)
        results[policy] = s
        print(f"{policy:<14}{s.avg_jct:>9.1f}s{s.p95_jct:>9.1f}s"
              f"{s.throughput_jobs_per_s * 60:>10.2f}{s.avg_queueing:>9.1f}s"
              f"{eng.scheduler.stats.ttl_hits:>9}")
    v, c = results["vllm"], results["continuum"]
    print(f"\nContinuum vs vLLM: {v.avg_jct / c.avg_jct:.2f}x JCT, "
          f"{c.throughput_jobs_per_s / v.throughput_jobs_per_s:.2f}x "
          f"throughput")


if __name__ == "__main__":
    main()
